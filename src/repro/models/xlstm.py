"""xLSTM blocks: chunkwise-parallel mLSTM (matrix memory, exponential gating,
max-stabilized) and recurrent sLSTM (scalar memory).

The mLSTM chunked scan shares its skeleton with the Mamba2 SSD scan (both are
decayed linear attention); the sLSTM is a true recurrence evaluated with
``lax.scan`` over time.  Layout: super-blocks of [1 sLSTM + (r-1) mLSTM]
where r = cfg.slstm_every (r=0 -> all mLSTM).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers as L

MIN_LOG = -30.0


def dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    di = cfg.ssm_expand * cfg.d_model
    h = cfg.num_heads
    return di, h, di // h


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg: ModelConfig, lead: Tuple[int, ...]) -> dict:
    d = cfg.d_model
    di, h, dh = dims(cfg)
    ks = jax.random.split(key, 8)
    ax = len(lead)
    return {
        "ln": jnp.zeros((*lead, d), jnp.float32),
        "up": L.dense_init(ks[0], (*lead, d, 2 * di), in_axis=ax),
        "conv_w": L.dense_init(ks[1], (*lead, di, cfg.ssm_conv), in_axis=ax + 1),
        "conv_b": jnp.zeros((*lead, di), jnp.float32),
        "wq": L.dense_init(ks[2], (*lead, di, di), in_axis=ax),
        "wk": L.dense_init(ks[3], (*lead, di, di), in_axis=ax),
        "wv": L.dense_init(ks[4], (*lead, di, di), in_axis=ax),
        "w_i": L.dense_init(ks[5], (*lead, di, h), in_axis=ax),
        "b_i": jnp.full((*lead, h), -3.0, jnp.float32),
        "w_f": L.dense_init(ks[6], (*lead, di, h), in_axis=ax),
        "b_f": jnp.full((*lead, h), 3.0, jnp.float32),  # open forget gate
        "norm": jnp.zeros((*lead, di), jnp.float32),
        "down": L.dense_init(ks[7], (*lead, di, d), in_axis=ax),
    }


def mlstm_specs(lead: Tuple[str, ...]) -> dict:
    return {
        "ln": P(*lead, "embed"),
        "up": P(*lead, "embed_fsdp", "conv_dim"),
        "conv_w": P(*lead, "conv_dim", None),
        "conv_b": P(*lead, "conv_dim"),
        "wq": P(*lead, "embed_fsdp", "conv_dim"),
        "wk": P(*lead, "embed_fsdp", "conv_dim"),
        "wv": P(*lead, "embed_fsdp", "conv_dim"),
        "w_i": P(*lead, "conv_dim", "ssm_heads"),
        "b_i": P(*lead, "ssm_heads"),
        "w_f": P(*lead, "conv_dim", "ssm_heads"),
        "b_f": P(*lead, "ssm_heads"),
        "norm": P(*lead, "conv_dim"),
        "down": P(*lead, "conv_dim", "embed_fsdp"),
    }


def _causal_conv(x, w, b):
    k = w.shape[-1]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    y = sum(
        pad[:, i : i + x.shape[1], :] * w[None, None, :, k - 1 - i].astype(x.dtype)
        for i in range(k)
    )
    return y + b.astype(x.dtype)


def _mlstm_inputs(blk: dict, x: jnp.ndarray, cfg: ModelConfig):
    """Shared projections: returns q,k,v (B,S,H,dh), gate logits (B,S,H), z."""
    b, s, _ = x.shape
    di, h, dh = dims(cfg)
    hidden = L.rms_norm(x, blk["ln"], cfg.norm_eps)
    up = jnp.einsum("bsd,dp->bsp", hidden, blk["up"].astype(x.dtype))
    xm, z = up[..., :di], up[..., di:]
    xc = jax.nn.silu(
        _causal_conv(xm, blk["conv_w"], blk["conv_b"]).astype(jnp.float32)
    ).astype(x.dtype)
    q = jnp.einsum("bsp,pq->bsq", xc, blk["wq"].astype(x.dtype))
    k = jnp.einsum("bsp,pq->bsq", xc, blk["wk"].astype(x.dtype))
    v = jnp.einsum("bsp,pq->bsq", xm, blk["wv"].astype(x.dtype))
    q = q.reshape(b, s, h, dh) / jnp.sqrt(jnp.float32(dh)).astype(x.dtype)
    k = k.reshape(b, s, h, dh)
    v = v.reshape(b, s, h, dh)
    i_log = (jnp.einsum("bsp,ph->bsh", xm, blk["w_i"].astype(x.dtype))
             .astype(jnp.float32) + blk["b_i"])
    f_raw = (jnp.einsum("bsp,ph->bsh", xm, blk["w_f"].astype(x.dtype))
             .astype(jnp.float32) + blk["b_f"])
    logf = jax.nn.log_sigmoid(f_raw)
    return q, k, v, i_log, logf, z


def _mlstm_out(blk, h_seq, z, x, cfg):
    b, s = x.shape[0], x.shape[1]
    di = h_seq.shape[-2] * h_seq.shape[-1]
    flat = h_seq.reshape(b, s, di).astype(x.dtype)
    y = L.rms_norm(flat, blk["norm"], cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return x + jnp.einsum("bsp,pd->bsd", y, blk["down"].astype(x.dtype))


def mlstm_block(blk: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Full-sequence chunkwise mLSTM block.  x: (B, S, D)."""
    b, s, _ = x.shape
    di, h, dh = dims(cfg)
    q, k, v, i_log, logf, z = _mlstm_inputs(blk, x, cfg)
    q_chunk = min(cfg.ssm_chunk, s)
    if s % q_chunk:
        q_chunk = s
    nc = s // q_chunk

    def chunk_fn(carry, inp):
        c_in, n_in, m_in = carry             # (B,H,N,P), (B,H,N), (B,H)
        qc, kc, vc, ic, fc = inp             # (B,Q,H,*) fp32 gates
        fq = jnp.cumsum(fc, axis=1)          # (B,Q,H) inclusive log-decay
        f_total = fq[:, -1]                  # (B,H)
        # log-weights of each key at chunk end and of state at queries
        b_t = f_total[:, None] - fq + ic     # (B,Q,H)
        a_q = fq + m_in[:, None]             # (B,Q,H) state decay at queries
        # intra-chunk pair decays d_qt = F_q - F_t + i_t  (t <= q)
        d_qt = fq[:, :, None, :] - fq[:, None, :, :] + ic[:, None, :, :]
        tpos = jnp.arange(qc.shape[1])
        causal = (tpos[:, None] >= tpos[None, :])[None, :, :, None]
        d_qt = jnp.where(causal, d_qt, MIN_LOG)
        m_q = jnp.maximum(a_q, d_qt.max(axis=2))           # (B,Q,H)
        # intra attention weights and kq products
        w_qt = jnp.exp(d_qt - m_q[:, :, None, :])          # (B,Q,T,H)
        kq = jnp.einsum("bqhn,bthn->bqth", qc.astype(jnp.float32),
                        kc.astype(jnp.float32))
        num = jnp.einsum("bqth,bthp->bqhp", w_qt * kq, vc.astype(jnp.float32))
        den = jnp.einsum("bqth,bqth->bqh", w_qt, kq)
        # inter-chunk (initial state) contribution
        w_state = jnp.exp(a_q - m_q)                       # (B,Q,H)
        cq = jnp.einsum("bhnp,bqhn->bqhp", c_in, qc.astype(jnp.float32))
        nq = jnp.einsum("bhn,bqhn->bqh", n_in, qc.astype(jnp.float32))
        num = num + w_state[..., None] * cq
        den = den + w_state * nq
        h_out = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_q))[..., None]
        # carry update (stabilized)
        m_next = jnp.maximum(f_total + m_in, b_t.max(axis=1))
        w_keys = jnp.exp(b_t - m_next[:, None])            # (B,Q,H)
        scale = jnp.exp(f_total + m_in - m_next)           # (B,H)
        c_out = scale[:, :, None, None] * c_in + jnp.einsum(
            "bthn,bthp,bth->bhnp", kc.astype(jnp.float32),
            vc.astype(jnp.float32), w_keys)
        n_out = scale[:, :, None] * n_in + jnp.einsum(
            "bthn,bth->bhn", kc.astype(jnp.float32), w_keys)
        return (c_out, n_out, m_next), h_out

    rc = lambda t: t.reshape(b, nc, q_chunk, *t.shape[2:]).swapaxes(0, 1)
    carry0 = (
        jnp.zeros((b, h, dh, dh), jnp.float32),
        jnp.zeros((b, h, dh), jnp.float32),
        jnp.full((b, h), MIN_LOG, jnp.float32),
    )
    _, h_chunks = jax.lax.scan(
        chunk_fn, carry0, (rc(q), rc(k), rc(v), rc(i_log), rc(logf))
    )
    h_seq = h_chunks.swapaxes(0, 1).reshape(b, s, h, dh)
    return _mlstm_out(blk, h_seq, z, x, cfg)


def mlstm_decode_block(blk, x, c_in, n_in, m_in, conv_state, cfg):
    """O(1) decode.  x (B,1,D); states (B,H,N,P)/(B,H,N)/(B,H)."""
    b = x.shape[0]
    di, h, dh = dims(cfg)
    hidden = L.rms_norm(x, blk["ln"], cfg.norm_eps)
    up = jnp.einsum("bsd,dp->bsp", hidden, blk["up"].astype(x.dtype))
    xm, z = up[..., :di], up[..., di:]
    full = jnp.concatenate([conv_state, xm], axis=1)
    conv = jnp.einsum("bkc,ck->bc", full, blk["conv_w"][:, ::-1].astype(x.dtype))
    xc = jax.nn.silu((conv + blk["conv_b"].astype(x.dtype)).astype(jnp.float32))
    xc = xc.astype(x.dtype)[:, None]
    new_conv = full[:, 1:]
    q = jnp.einsum("bsp,pq->bsq", xc, blk["wq"].astype(x.dtype))
    k = jnp.einsum("bsp,pq->bsq", xc, blk["wk"].astype(x.dtype))
    v = jnp.einsum("bsp,pq->bsq", xm, blk["wv"].astype(x.dtype))
    q = (q.reshape(b, h, dh) / jnp.sqrt(jnp.float32(dh)).astype(x.dtype)
         ).astype(jnp.float32)
    k = k.reshape(b, h, dh).astype(jnp.float32)
    v = v.reshape(b, h, dh).astype(jnp.float32)
    i_log = (jnp.einsum("bsp,ph->bsh", xm, blk["w_i"].astype(x.dtype))
             .astype(jnp.float32) + blk["b_i"])[:, 0]
    logf = jax.nn.log_sigmoid(
        (jnp.einsum("bsp,ph->bsh", xm, blk["w_f"].astype(x.dtype))
         .astype(jnp.float32) + blk["b_f"])[:, 0]
    )
    m_next = jnp.maximum(logf + m_in, i_log)
    f_w = jnp.exp(logf + m_in - m_next)
    i_w = jnp.exp(i_log - m_next)
    c_out = f_w[:, :, None, None] * c_in + i_w[:, :, None, None] * (
        k[:, :, :, None] * v[:, :, None, :]
    )
    n_out = f_w[:, :, None] * n_in + i_w[:, :, None] * k
    num = jnp.einsum("bhnp,bhn->bhp", c_out, q)
    den = jnp.einsum("bhn,bhn->bh", n_out, q)
    h_t = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_next))[..., None]
    out = _mlstm_out(blk, h_t[:, None], z, x, cfg)
    return out, c_out, n_out, m_next, new_conv


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg: ModelConfig, lead: Tuple[int, ...]) -> dict:
    d = cfg.d_model
    di, h, dh = dims(cfg)
    ks = jax.random.split(key, 4)
    ax = len(lead)
    return {
        "ln": jnp.zeros((*lead, d), jnp.float32),
        "w_in": L.dense_init(ks[0], (*lead, d, 4 * di), in_axis=ax),
        "r": L.dense_init(ks[1], (*lead, h, dh, 4 * dh), in_axis=ax + 1) * 0.1,
        "b": jnp.concatenate(
            [
                jnp.full((*lead, di), -3.0),   # i
                jnp.full((*lead, di), 3.0),    # f
                jnp.zeros((*lead, di)),        # z
                jnp.zeros((*lead, di)),        # o
            ],
            axis=-1,
        ).astype(jnp.float32),
        "norm": jnp.zeros((*lead, di), jnp.float32),
        "down": L.dense_init(ks[2], (*lead, di, d), in_axis=ax),
    }


def slstm_specs(lead: Tuple[str, ...]) -> dict:
    return {
        "ln": P(*lead, "embed"),
        "w_in": P(*lead, "embed_fsdp", "conv_dim"),
        "r": P(*lead, "ssm_heads", None, None),
        "b": P(*lead, "conv_dim"),
        "norm": P(*lead, "conv_dim"),
        "down": P(*lead, "conv_dim", "embed_fsdp"),
    }


def _slstm_cell(blk, wx_t, state, cfg):
    """One recurrence step. wx_t: (B, 4*di); state: (c, n, h, m) each (B, di)."""
    di, h, dh = dims(cfg)
    c, n, hid, m = state
    b_sz = wx_t.shape[0]
    hr = hid.reshape(b_sz, h, dh)
    rec = jnp.einsum("bhd,hde->bhe", hr, blk["r"].astype(hid.dtype))
    raw = wx_t + rec.reshape(b_sz, 4 * di) + blk["b"].astype(wx_t.dtype)
    raw = raw.astype(jnp.float32)
    i_r, f_r, z_r, o_r = jnp.split(raw, 4, axis=-1)
    logf = jax.nn.log_sigmoid(f_r)
    m_next = jnp.maximum(logf + m, i_r)
    i_w = jnp.exp(i_r - m_next)
    f_w = jnp.exp(logf + m - m_next)
    c_next = f_w * c + i_w * jnp.tanh(z_r)
    n_next = f_w * n + i_w
    h_next = jax.nn.sigmoid(o_r) * c_next / jnp.maximum(n_next, 1e-6)
    return c_next, n_next, h_next, m_next


def slstm_block(blk: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Recurrent sLSTM block over the full sequence (lax.scan over time)."""
    b, s, _ = x.shape
    di, _, _ = dims(cfg)
    hidden = L.rms_norm(x, blk["ln"], cfg.norm_eps)
    wx = jnp.einsum("bsd,dp->bsp", hidden, blk["w_in"].astype(x.dtype))
    state0 = tuple(
        jnp.zeros((b, di), jnp.float32) for _ in range(3)
    ) + (jnp.full((b, di), MIN_LOG, jnp.float32),)

    def step(state, wx_t):
        new = _slstm_cell(blk, wx_t, state, cfg)
        return new, new[2]

    _, h_seq = jax.lax.scan(step, state0, wx.swapaxes(0, 1))
    h_seq = h_seq.swapaxes(0, 1).astype(x.dtype)           # (B,S,di)
    y = L.rms_norm(h_seq, blk["norm"], cfg.norm_eps)
    return x + jnp.einsum("bsp,pd->bsd", y, blk["down"].astype(x.dtype))


def slstm_decode_block(blk, x, state, cfg):
    hidden = L.rms_norm(x, blk["ln"], cfg.norm_eps)
    wx = jnp.einsum("bsd,dp->bsp", hidden, blk["w_in"].astype(x.dtype))[:, 0]
    new = _slstm_cell(blk, wx, state, cfg)
    y = L.rms_norm(new[2][:, None].astype(x.dtype), blk["norm"], cfg.norm_eps)
    out = x + jnp.einsum("bsp,pd->bsd", y, blk["down"].astype(x.dtype))
    return out, new
