"""Mixture-of-Experts FFN with top-k routing and capacity-based dispatch.

GShard/Switch-style: tokens are routed to their top-k experts, dispatched by
scatter into per-expert capacity buffers (so compiled FLOPs reflect *active*
experts only — required for the MoE roofline's 6*N_active*D accounting), run
through batched expert FFNs, and combined with router weights.  Experts shard
over the "model" mesh axis (expert parallelism); the dispatch/combine scatter
+ gather become the MoE all-to-all under GSPMD.

The router's top-k uses the same merge primitive as the paper's partitioned
Top-K (core/partition.py): experts == partitions, k == experts_per_token.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.sharding.rules import constrain


def init_moe(key, cfg: ModelConfig, layers: int) -> dict:
    ks = jax.random.split(key, 4)
    nl, d, ff, e = layers, cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "router": L.dense_init(ks[0], (nl, d, e), in_axis=1),
        "w_gate": L.dense_init(ks[1], (nl, e, d, ff), in_axis=2),
        "w_up": L.dense_init(ks[2], (nl, e, d, ff), in_axis=2),
        "w_down": L.dense_init(ks[3], (nl, e, ff, d), in_axis=2),
    }


def moe_specs(cfg: ModelConfig, layers: bool) -> dict:
    lead = ("layers",) if layers else ()
    return {
        "router": P(*lead, "embed", None),
        "w_gate": P(*lead, "experts", "embed_fsdp", "expert_mlp"),
        "w_up": P(*lead, "experts", "embed_fsdp", "expert_mlp"),
        "w_down": P(*lead, "experts", "expert_mlp", "embed_fsdp"),
    }


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    if tokens <= 256:
        # decode / tiny batches: drop-free (worst case all tokens co-route)
        return tokens * cfg.experts_per_token
    cap = int(tokens * cfg.experts_per_token * cfg.moe_capacity_factor
              / cfg.num_experts)
    return max(cap, cfg.experts_per_token)


def moe_mlp(p: dict, x: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (y, aux_loss)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    t = b * s
    cap = _capacity(t, cfg)
    xt = x.reshape(t, d)

    # --- routing (top-k over experts; softmax over the selected gates) ---
    logits = jnp.einsum("td,de->te", xt, p["router"].astype(x.dtype))
    logits = logits.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)          # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9
    )

    # --- load-balancing auxiliary loss (Switch-style) ---
    me = probs.mean(axis=0)                                   # mean router prob
    ce = jnp.zeros((e,), jnp.float32).at[expert_idx[:, 0]].add(1.0) / t
    aux = e * jnp.sum(me * ce)

    # --- capacity assignment: position of each (token, slot) in its expert ---
    flat_expert = expert_idx.reshape(-1)                      # (T*k,)
    onehot = jax.nn.one_hot(flat_expert, e, dtype=jnp.int32)  # (T*k, E)
    pos_in_expert = (jnp.cumsum(onehot, axis=0) - onehot)[
        jnp.arange(t * k), flat_expert
    ]
    keep = pos_in_expert < cap                                # overflow dropped

    # --- dispatch: scatter tokens into (E, C, D) buffers (the all-to-all) ---
    src = jnp.repeat(xt, k, axis=0)                           # (T*k, D)
    safe_pos = jnp.where(keep, pos_in_expert, cap - 1)
    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[flat_expert, safe_pos].add(
        jnp.where(keep[:, None], src, 0).astype(x.dtype)
    )
    buf = constrain(buf, ("experts", "expert_cap", "embed"))

    # --- expert FFNs (batched over E; sharded over "model" via experts) ---
    gate = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(x.dtype))
    up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(x.dtype))
    act = (jax.nn.silu(gate.astype(jnp.float32)) * up.astype(jnp.float32)
           ).astype(x.dtype)
    out = jnp.einsum("ecf,efd->ecd", act, p["w_down"].astype(x.dtype))
    out = constrain(out, ("experts", "expert_cap", "embed"))

    # --- combine: gather each (token, slot)'s result, weight, and sum ---
    gathered = out[flat_expert, safe_pos]                     # (T*k, D)
    gathered = jnp.where(keep[:, None], gathered, 0)
    w = gate_vals.reshape(-1)[:, None].astype(x.dtype)
    y = (gathered * w).reshape(t, k, d).sum(axis=1)
    return y.reshape(b, s, d), aux
