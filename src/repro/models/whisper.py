"""Whisper-style encoder-decoder backbone (conv frontend stubbed).

``input_specs()`` supplies precomputed frame embeddings (batch, S_enc, d) —
per the assignment the modality frontend is a stub.  Encoder: non-causal
self-attention, sinusoidal positions, GELU MLP, LayerNorm.  Decoder: causal
self-attention + cross-attention, learned positions.  Convention (DESIGN.md
§4): encoder length == decoder length == the shape's seq_len.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers as L


def sinusoids(length: int, channels: int) -> jnp.ndarray:
    """Whisper's sinusoidal position embedding for the encoder."""
    log_timescale = np.log(10000.0) / (channels // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(channels // 2, dtype=jnp.float32))
    scaled = jnp.arange(length, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)], axis=1)


def _ln_params(lead, d):
    return {"w": jnp.ones((*lead, d), jnp.float32),
            "b": jnp.zeros((*lead, d), jnp.float32)}


def _ln_specs(lead):
    return {"w": P(*lead, "embed"), "b": P(*lead, "embed")}


def init_params(key, cfg: ModelConfig, max_seq: int) -> dict:
    ks = jax.random.split(key, 8)
    ne, nd, d = cfg.encoder_layers, cfg.num_layers, cfg.d_model
    enc_blocks = {
        "ln1": _ln_params((ne,), d),
        "attn": L.init_attention(ks[0], cfg, layers=ne),
        "ln2": _ln_params((ne,), d),
        "mlp": L.init_mlp(ks[1], d, cfg.d_ff, layers=ne, gated=False),
    }
    dec_blocks = {
        "ln1": _ln_params((nd,), d),
        "self_attn": L.init_attention(ks[2], cfg, layers=nd),
        "ln2": _ln_params((nd,), d),
        "cross_attn": L.init_attention(ks[3], cfg, layers=nd),
        "ln3": _ln_params((nd,), d),
        "mlp": L.init_mlp(ks[4], d, cfg.d_ff, layers=nd, gated=False),
    }
    return {
        "embed": L.init_embedding(ks[5], cfg),
        "dec_pos": L.embed_init(ks[6], (max_seq, d)),
        "enc_blocks": enc_blocks,
        "enc_ln_f": _ln_params((), d),
        "dec_blocks": dec_blocks,
        "dec_ln_f": _ln_params((), d),
    }


def param_specs(cfg: ModelConfig) -> dict:
    lead = ("layers",)
    enc = {
        "ln1": _ln_specs(lead),
        "attn": L.attention_specs(cfg, layers=True),
        "ln2": _ln_specs(lead),
        "mlp": L.mlp_specs(layers=True, gated=False),
    }
    dec = {
        "ln1": _ln_specs(lead),
        "self_attn": L.attention_specs(cfg, layers=True),
        "ln2": _ln_specs(lead),
        "cross_attn": L.attention_specs(cfg, layers=True),
        "ln3": _ln_specs(lead),
        "mlp": L.mlp_specs(layers=True, gated=False),
    }
    return {
        "embed": L.embedding_specs(cfg),
        "dec_pos": P("seq", "embed_fsdp"),
        "enc_blocks": enc,
        "enc_ln_f": _ln_specs(()),
        "dec_blocks": dec,
        "dec_ln_f": _ln_specs(()),
    }


def _ln(x, p, eps):
    return L.layer_norm(x, p["w"], p["b"], eps)


def _remat(fn, cfg):
    return jax.checkpoint(fn) if cfg.remat != "none" else fn


def encode(params, cfg: ModelConfig, frame_embeds: jnp.ndarray) -> jnp.ndarray:
    """frame_embeds: (B, S_enc, D) precomputed (conv frontend stub)."""
    x = frame_embeds.astype(L.cdtype(cfg))
    x = x + sinusoids(x.shape[1], cfg.d_model).astype(x.dtype)[None]
    positions = jnp.arange(x.shape[1])[None, :]

    def block(x, blk):
        h = _ln(x, blk["ln1"], cfg.norm_eps)
        q, k, v = L.qkv_project(blk["attn"], h, cfg, positions)
        attn = L.blockwise_attention(q, k, v, causal=False)
        x = x + L.attention_out(blk["attn"], attn, cfg)
        h = _ln(x, blk["ln2"], cfg.norm_eps)
        return x + L.gelu_mlp(blk["mlp"], h)

    block = _remat(block, cfg)

    def scan_body(x, blk):
        return block(x, blk), None

    x, _ = jax.lax.scan(scan_body, x, params["enc_blocks"])
    return _ln(x, params["enc_ln_f"], cfg.norm_eps)


def _cross_attention(blk_key, blk, x, enc_out, cfg):
    """Decoder cross-attention: q from x, kv from encoder output (no RoPE)."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    p = blk[blk_key]
    dt = x.dtype
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dh->bsh", enc_out, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dh->bsh", enc_out, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q, k, v = q + p["bq"].astype(dt), k + p["bk"].astype(dt), v + p["bv"].astype(dt)
    q = q.reshape(b, s, cfg.num_heads, hd)
    k = k.reshape(b, enc_out.shape[1], cfg.num_kv_heads, hd)
    v = v.reshape(b, enc_out.shape[1], cfg.num_kv_heads, hd)
    attn = L.blockwise_attention(q, k, v, causal=False)
    return L.attention_out(p, attn, cfg)


def decode_train(params, cfg: ModelConfig, tokens, enc_out) -> jnp.ndarray:
    x = L.embed_tokens(params["embed"], tokens, cfg)
    x = x + params["dec_pos"][: x.shape[1]].astype(x.dtype)[None]
    positions = jnp.arange(x.shape[1])[None, :]

    def block(x, blk):
        h = _ln(x, blk["ln1"], cfg.norm_eps)
        q, k, v = L.qkv_project(blk["self_attn"], h, cfg, positions)
        attn = L.blockwise_attention(q, k, v, causal=True)
        x = x + L.attention_out(blk["self_attn"], attn, cfg)
        h = _ln(x, blk["ln2"], cfg.norm_eps)
        x = x + _cross_attention("cross_attn", blk, h, enc_out, cfg)
        h = _ln(x, blk["ln3"], cfg.norm_eps)
        return x + L.gelu_mlp(blk["mlp"], h)

    block = _remat(block, cfg)

    def scan_body(x, blk):
        return block(x, blk), None

    x, _ = jax.lax.scan(scan_body, x, params["dec_blocks"])
    return _ln(x, params["dec_ln_f"], cfg.norm_eps)


def loss_fn(params, cfg: ModelConfig, batch: dict) -> jnp.ndarray:
    enc_out = encode(params, cfg, batch["frame_embeds"])
    x = decode_train(params, cfg, batch["tokens"], enc_out)
    logits = L.lm_logits(params["embed"], x, cfg)
    return L.cross_entropy_loss(logits, batch["labels"], batch.get("loss_mask"))


# ---------------------------------------------------------------------------
# Serving: decoder decode step with self-KV cache + precomputed cross-KV
# ---------------------------------------------------------------------------

def cache_shape(cfg: ModelConfig, batch: int, seq: int) -> dict:
    hd = cfg.resolved_head_dim
    nd = cfg.num_layers
    dt = jnp.dtype(cfg.dtype)
    kv = (nd, batch, cfg.num_kv_heads, seq, hd)
    return {
        "k": jax.ShapeDtypeStruct(kv, dt),
        "v": jax.ShapeDtypeStruct(kv, dt),
        "cross_k": jax.ShapeDtypeStruct(kv, dt),
        "cross_v": jax.ShapeDtypeStruct(kv, dt),
        "cross_len": jax.ShapeDtypeStruct((), jnp.int32),
    }


def cache_specs(cfg: ModelConfig) -> dict:
    kv = P("layers", "batch", "kv_heads", "cache_seq", None)
    return {"k": kv, "v": kv, "cross_k": kv, "cross_v": kv, "cross_len": P()}


def init_cache(cfg: ModelConfig, batch: int, seq: int) -> dict:
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_shape(cfg, batch, seq)
    )


def decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    x = L.embed_tokens(params["embed"], tokens, cfg)
    x = x + params["dec_pos"][pos][None, None].astype(x.dtype)

    def scan_body(x, inp):
        blk, kc, vc, ck, cv = inp
        h = _ln(x, blk["ln1"], cfg.norm_eps)
        q, k, v = L.qkv_project(blk["self_attn"], h, cfg, pos[None, None])
        kc = L.cache_insert(kc, k, pos)
        vc = L.cache_insert(vc, v, pos)
        attn = L.decode_attention(q, kc, vc, pos + 1)
        x = x + L.attention_out(blk["self_attn"], attn, cfg)
        # cross attention against precomputed encoder KV
        h = _ln(x, blk["ln2"], cfg.norm_eps)
        p = blk["cross_attn"]
        b = x.shape[0]
        hd = cfg.resolved_head_dim
        q2 = jnp.einsum("bsd,dh->bsh", h, p["wq"].astype(x.dtype))
        if cfg.qkv_bias:
            q2 = q2 + p["bq"].astype(x.dtype)
        q2 = q2.reshape(b, 1, cfg.num_heads, hd)
        attn2 = L.decode_attention(q2, ck, cv, cache["cross_len"])
        x = x + L.attention_out(p, attn2, cfg)
        h = _ln(x, blk["ln3"], cfg.norm_eps)
        x = x + L.gelu_mlp(blk["mlp"], h)
        return x, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(
        scan_body,
        x,
        (params["dec_blocks"], cache["k"], cache["v"],
         cache["cross_k"], cache["cross_v"]),
    )
    x = _ln(x, params["dec_ln_f"], cfg.norm_eps)
    logits = L.lm_logits(params["embed"], x, cfg)
    return logits[:, 0], {
        "k": k_new, "v": v_new,
        "cross_k": cache["cross_k"], "cross_v": cache["cross_v"],
        "cross_len": cache["cross_len"],
    }


def prefill(params, cfg: ModelConfig, batch: dict) -> jnp.ndarray:
    """Encoder + full decoder pass; returns last-position logits."""
    enc_out = encode(params, cfg, batch["frame_embeds"])
    x = decode_train(params, cfg, batch["tokens"], enc_out)
    return L.lm_logits(params["embed"], x[:, -1:], cfg)[:, 0]


def build_cross_cache(params, cfg: ModelConfig, enc_out: jnp.ndarray,
                      pad_to: int = 0):
    """Precompute per-layer cross-attention K/V from encoder output
    (heads-major layout).  Serving runs this once per request after encode."""
    b, s_enc, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    s_out = max(s_enc, pad_to)

    def one_layer(blk):
        p = blk["cross_attn"]
        dt = enc_out.dtype
        k = jnp.einsum("bsd,dh->bsh", enc_out, p["wk"].astype(dt))
        v = jnp.einsum("bsd,dh->bsh", enc_out, p["wv"].astype(dt))
        if cfg.qkv_bias:
            k, v = k + p["bk"].astype(dt), v + p["bv"].astype(dt)
        k = k.reshape(b, s_enc, cfg.num_kv_heads, hd).swapaxes(1, 2)
        v = v.reshape(b, s_enc, cfg.num_kv_heads, hd).swapaxes(1, 2)
        pad = [(0, 0), (0, 0), (0, s_out - s_enc), (0, 0)]
        return jnp.pad(k, pad), jnp.pad(v, pad)

    ks, vs = jax.vmap(one_layer)(params["dec_blocks"])
    return ks, vs
