"""Unified model API: one dispatch point for all 10 assigned architectures.

``get_model(cfg)`` returns a ModelAPI whose members close over the config:
loss_fn / prefill / decode_step plus shape-only helpers (batch_spec,
cache_shape) used by the multi-pod dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer, whisper, xlstm_lm, zamba


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init_params: Callable[..., Any]
    param_specs: Callable[[], Any]
    loss_fn: Callable[[Any, Dict], jnp.ndarray]
    prefill: Callable[[Any, Dict], jnp.ndarray]
    decode_step: Callable[..., Any]
    cache_shape: Callable[[int, int], Dict]
    cache_specs: Callable[[], Dict]
    init_cache: Callable[[int, int], Dict]
    batch_spec: Callable[[ShapeConfig], Dict]
    batch_logical: Callable[[ShapeConfig], Dict]


def _token_batch_spec(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input (dry-run input_specs)."""
    b, s = shape.global_batch, shape.seq_len
    tok = lambda *sh: jax.ShapeDtypeStruct(sh, jnp.int32)
    emb = lambda *sh: jax.ShapeDtypeStruct(sh, jnp.dtype(cfg.dtype))
    if shape.kind == "decode":
        return {
            "cache": None,  # filled by caller via cache_shape
            "tokens": tok(b, 1),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }
    if cfg.family == "audio":
        d = {"frame_embeds": emb(b, s, cfg.d_model), "tokens": tok(b, s)}
    elif cfg.family == "vlm":
        ft = cfg.frontend_tokens
        d = {"prefix_embeds": emb(b, ft, cfg.d_model), "tokens": tok(b, s - ft)}
    else:
        d = {"tokens": tok(b, s)}
    if shape.kind == "train":
        d["labels"] = tok(*d["tokens"].shape)
    return d


def _token_batch_logical(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    if shape.kind == "decode":
        return {"cache": None, "tokens": P("batch"), "pos": P()}
    out = {"tokens": P("batch", "seq")}
    if cfg.family == "audio":
        out["frame_embeds"] = P("batch", "seq", "embed")
    if cfg.family == "vlm":
        out["prefix_embeds"] = P("batch", "seq", "embed")
    if shape.kind == "train":
        out["labels"] = P("batch", "seq")
    return out


def get_model(cfg: ModelConfig) -> ModelAPI:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        mod = transformer

        def loss(params, batch):
            return mod.loss_fn(params, cfg, batch)

        def pre(params, batch):
            return mod.prefill(
                params, cfg, batch["tokens"], batch.get("prefix_embeds")
            )

    elif fam == "hybrid":
        mod = zamba

        def loss(params, batch):
            return mod.loss_fn(params, cfg, batch)

        def pre(params, batch):
            return mod.prefill(params, cfg, batch["tokens"])

    elif fam == "ssm":
        mod = xlstm_lm

        def loss(params, batch):
            return mod.loss_fn(params, cfg, batch)

        def pre(params, batch):
            return mod.prefill(params, cfg, batch["tokens"])

    elif fam == "audio":
        mod = whisper

        def loss(params, batch):
            return mod.loss_fn(params, cfg, batch)

        def pre(params, batch):
            return mod.prefill(params, cfg, batch)

    else:
        raise ValueError(f"unknown family {fam!r}")

    return ModelAPI(
        cfg=cfg,
        init_params=lambda key, max_seq=4096: mod.init_params(key, cfg, max_seq),
        param_specs=lambda: mod.param_specs(cfg),
        loss_fn=loss,
        prefill=pre,
        decode_step=lambda params, cache, tokens, pos: mod.decode_step(
            params, cfg, cache, tokens, pos
        ),
        cache_shape=lambda batch, seq: mod.cache_shape(cfg, batch, seq),
        cache_specs=lambda: mod.cache_specs(cfg),
        init_cache=lambda batch, seq: mod.init_cache(cfg, batch, seq),
        batch_spec=lambda shape: _token_batch_spec(cfg, shape),
        batch_logical=lambda shape: _token_batch_logical(cfg, shape),
    )


# ---------------------------------------------------------------------------
# Analytic parameter counts (for MODEL_FLOPS = 6*N*D in the roofline)
# ---------------------------------------------------------------------------

def count_params_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    """Exact count via abstract init (no allocation); MoE active subset
    counts each token's experts_per_token of num_experts expert FFNs."""
    api = get_model(cfg)
    shapes = jax.eval_shape(lambda: api.init_params(jax.random.key(0), 128))
    total = 0
    moe_expert = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if "moe" in keys and any(k.startswith("w_") for k in keys if k):
            moe_expert += n
    if active_only and cfg.num_experts > 0:
        frac = cfg.experts_per_token / cfg.num_experts
        total = total - moe_expert + int(moe_expert * frac)
    return total
