"""Logical-axis sharding rules for the production mesh."""
from repro.sharding.rules import (
    ShardingRules,
    DEFAULT_RULES,
    logical_to_spec,
    shard_params,
    constrain,
)
