"""Logical axis -> mesh axis mapping with divisibility fallback (MaxText-style).

Every parameter / activation dimension is named with a *logical* axis; the
rules table maps logical axes to mesh axes.  If a dimension is not divisible
by the mapped mesh-axis size the mapping is dropped for that tensor (the
fallback keeps e.g. smollm's 15 heads compiling on a 16-way model axis by
replicating attention weights while the MLP stays sharded — DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

LogicalAxis = Optional[str]
MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Mapping from logical axis names to mesh axis names."""

    rules: Tuple[Tuple[str, MeshAxes], ...]

    def lookup(self, logical: LogicalAxis) -> MeshAxes:
        if logical is None:
            return None
        for name, target in self.rules:
            if name == logical:
                return target
        return None

    def replace(self, **overrides: MeshAxes) -> "ShardingRules":
        new = dict(self.rules)
        new.update(overrides)
        return ShardingRules(tuple(new.items()))


# Production defaults: batch is pure DP over (pod, data); weights are
# FSDP-sharded over "data" on their input/embed dim and tensor-sharded over
# "model" on heads/mlp/vocab/experts dims; optimizer state follows params.
DEFAULT_RULES = ShardingRules(
    rules=(
        ("batch", ("pod", "data")),
        # serving plane (launch.mesh.make_serving_mesh): the top-k index's
        # leading shard dim and the query batch's replica fan-out.  Both drop
        # harmlessly on model meshes without these axes (_present filters).
        ("topk_shards", "shard"),
        ("topk_queries", "replica"),
        ("seq", None),
        # decode caches: kv_heads (earlier dim) takes "model" when divisible;
        # otherwise the seq dim picks the axis up (greedy per-tensor dedup) —
        # either way the cache is never replicated on the model axis (§Perf B)
        ("cache_seq", "model"),
        ("embed", None),           # activations: d_model replicated
        ("embed_fsdp", "data"),    # weights: d_model dim sharded (ZeRO-3/FSDP)
        ("heads", "model"),
        ("kv_heads", "model"),
        ("mlp", "model"),
        ("vocab", "model"),
        # experts take the model axis when divisible (EP); otherwise the
        # greedy per-tensor dedup lets expert_mlp pick the axis up instead
        # (TP inside each expert) — without this, mixtral's 8 experts on a
        # 16-way axis silently replicate all expert FFN compute (§Perf A).
        ("experts", "model"),
        ("expert_mlp", "model"),
        # capacity-dim sharding is arch-dependent: archs whose expert count
        # cannot take the model axis override this to ("pod", "data") so the
        # (E, C, d) dispatch buffers aren't replicated (§Perf A, iter. A3)
        ("expert_cap", None),
        ("layers", None),
        ("ssm_state", None),
        ("ssm_heads", "model"),
        ("conv_dim", "model"),
    )
)


def _axis_size(mesh: Mesh, target: MeshAxes) -> int:
    if target is None:
        return 1
    if isinstance(target, str):
        return mesh.shape.get(target, 1)
    size = 1
    for t in target:
        size *= mesh.shape.get(t, 1)
    return size


def _present(mesh: Mesh, target: MeshAxes) -> MeshAxes:
    """Drop mesh axes that don't exist in this mesh (e.g. 'pod' single-pod)."""
    if target is None:
        return None
    if isinstance(target, str):
        return target if target in mesh.shape else None
    kept = tuple(t for t in target if t in mesh.shape)
    if not kept:
        return None
    # unwrap 1-tuples: P(("data",)) and P("data") shard identically, but
    # PartitionSpec equality distinguishes them on current jax
    return kept[0] if len(kept) == 1 else kept


def logical_to_spec(
    logical_dims: Sequence[LogicalAxis],
    shape: Sequence[int],
    mesh: Mesh,
    rules: ShardingRules = DEFAULT_RULES,
) -> P:
    """Build a PartitionSpec, dropping non-divisible / absent mappings."""
    out = []
    used: set = set()
    for dim, logical in zip(shape, logical_dims):
        target = _present(mesh, rules.lookup(logical))
        if target is not None:
            flat = (target,) if isinstance(target, str) else target
            if any(t in used for t in flat):
                target = None  # a mesh axis may shard only one dim
        if target is not None and dim % _axis_size(mesh, target) != 0:
            target = None  # divisibility fallback
        if target is not None:
            flat = (target,) if isinstance(target, str) else target
            used.update(flat)
        out.append(target)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def logical_sharding(
    logical_dims: Sequence[LogicalAxis],
    shape: Sequence[int],
    mesh: Mesh,
    rules: ShardingRules = DEFAULT_RULES,
) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(logical_dims, shape, mesh, rules))


def shard_params(
    params: Any, specs: Any, mesh: Mesh, rules: ShardingRules = DEFAULT_RULES
) -> Any:
    """Tree of NamedShardings for a (params, logical-specs) tree pair.

    ``specs`` leaves are PartitionSpec objects carrying *logical* names, e.g.
    ``P('layers', 'embed_fsdp', 'mlp')``; they are resolved per-tensor against
    the mesh with divisibility fallback.
    """
    return jax.tree.map(
        lambda p, s: logical_sharding(tuple(s), p.shape, mesh, rules),
        params,
        specs,
    )


_ACTIVE_RULES = [DEFAULT_RULES]


class use_rules:
    """Context manager scoping the rules consulted by in-model constrain()
    calls — how per-arch sharding_overrides reach with_sharding_constraint."""

    def __init__(self, rules: ShardingRules):
        self.rules = rules

    def __enter__(self):
        _ACTIVE_RULES.append(self.rules)
        return self.rules

    def __exit__(self, *exc):
        _ACTIVE_RULES.pop()
        return False


def active_rules() -> ShardingRules:
    return _ACTIVE_RULES[-1]


def constrain(
    x: jax.Array,
    logical_dims: Sequence[LogicalAxis],
    mesh: Optional[Mesh] = None,
    rules: Optional[ShardingRules] = None,
):
    """with_sharding_constraint by logical dims; no-op outside a mesh context."""
    mesh = mesh or _current_mesh()
    if mesh is None or mesh.empty:
        return x
    rules = rules or active_rules()
    return jax.lax.with_sharding_constraint(
        x, logical_sharding(logical_dims, x.shape, mesh, rules)
    )


def _current_mesh() -> Optional[Mesh]:
    try:
        env = jax._src.mesh.thread_resources.env  # physical mesh context
        return env.physical_mesh
    except Exception:  # pragma: no cover - defensive
        return None
