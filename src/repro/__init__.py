"""repro: approximate Top-K SpMV embedding similarity, reproduced on TPU in JAX."""
__version__ = "1.0.0"
