"""qwen2.5-3b [dense]: GQA with QKV bias. [hf:Qwen/Qwen2.5-0.5B; hf]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    d_ff=11008,
    vocab_size=151936,
    qkv_bias=True,
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=2,
    d_model=64,
    num_heads=8,
    num_kv_heads=1,
    d_ff=128,
    vocab_size=512,
    dtype="float32",
    vocab_pad_multiple=8,
)
