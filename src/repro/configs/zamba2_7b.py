"""zamba2-7b [hybrid]: Mamba2 backbone + shared attention block.
[arXiv:2411.15242; unverified]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=128,
    shared_attn_every=6,
    subquadratic=True,        # SSM state constant; shared-attn KV linear
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=5,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    ssm_state=16,
    ssm_head_dim=16,
    ssm_chunk=16,
    shared_attn_every=2,
    dtype="float32",
    vocab_pad_multiple=8,
)
