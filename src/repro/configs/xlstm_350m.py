"""xlstm-350m [ssm]: sLSTM + mLSTM blocks. [arXiv:2405.04517; unverified]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,                    # xLSTM blocks carry their own up/down projs
    vocab_size=50304,
    ssm_expand=2,
    ssm_chunk=128,
    slstm_every=4,             # blocks: [sLSTM, mLSTM, mLSTM, mLSTM] x 6
    subquadratic=True,         # constant-size recurrent state
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    vocab_size=512,
    ssm_chunk=16,
    slstm_every=4,
    dtype="float32",
    vocab_pad_multiple=8,
)
