"""Model / shape / run configuration dataclasses shared by all architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


def pad_to_multiple(n: int, m: int) -> int:
    return -(-n // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One architecture.  Field values come from the assigned public configs."""

    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25

    # SSM (Mamba2-style)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # xLSTM
    slstm_every: int = 0           # every n-th block is an sLSTM block (0: none)

    # attention details
    sliding_window: int = 0        # 0 -> full causal
    qkv_bias: bool = False
    rope_theta: float = 10000.0

    # hybrid (zamba-style): shared attention block applied every n mamba blocks
    shared_attn_every: int = 0

    # encoder-decoder (whisper-style)
    is_encoder_decoder: bool = False
    encoder_layers: int = 0

    # modality frontend stub: precomputed embeddings prepended to the sequence
    frontend: str = "none"         # none | audio_frames | vision_patches
    frontend_tokens: int = 0       # e.g. 256 vision patches

    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: str = "full"            # none | full | dots
    scan_layers: bool = True       # False: unroll (in-place cache decode)
    kv_quant: bool = False         # int8 KV cache (paper's fixed-point idea
                                   # applied to decode HBM traffic; §Perf B4)
    vocab_pad_multiple: int = 256  # 16 model shards x 128 lanes

    # long-context capability marker (sub-quadratic decode memory)
    subquadratic: bool = False

    # per-arch sharding-rule overrides, applied over DEFAULT_RULES by the
    # launchers (e.g. mixtral: shard MoE dispatch capacity over data because
    # its 8 experts cannot take the 16-way model axis — DESIGN.md §5)
    sharding_overrides: Tuple[Tuple[str, object], ...] = ()

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        return pad_to_multiple(self.vocab_size, self.vocab_pad_multiple)

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6*N*D model FLOPs)."""
        from repro.models.model_zoo import count_params_analytic

        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.model_zoo import count_params_analytic

        return count_params_analytic(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell: (kind, seq_len, global_batch)."""

    name: str
    kind: str                      # train | prefill | decode
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeConfig("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524288, 1)

ALL_SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """long_500k requires sub-quadratic attention (DESIGN.md §4 skip list)."""
    if shape.name == "long_500k" and not model.subquadratic:
        return False, "full quadratic attention; long_500k skipped per spec"
    return True, ""


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Training-run hyperparameters (launcher-level)."""

    learning_rate: float = 3e-4
    warmup_steps: int = 100
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    microbatches: int = 1          # grad-accumulation (overlaps reduce/backward)
    grad_dtype: str = "float32"    # float32 | bfloat16 (compressed reduction)
    steps: int = 100
    seed: int = 0
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    async_checkpoint: bool = True
    step_timeout_s: float = 0.0    # >0: straggler watchdog
