"""qwen2-72b [dense]: the largest assigned cell; FSDP+TP required.
[arXiv:2407.10671; hf]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=2,
    d_model=64,
    num_heads=8,
    num_kv_heads=1,
    d_ff=128,
    vocab_size=512,
    dtype="float32",
    vocab_pad_multiple=8,
)
