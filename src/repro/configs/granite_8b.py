"""granite-8b [dense]: llama-arch, code. [arXiv:2405.04324; hf]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=49152,
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    d_ff=128,
    vocab_size=512,
    dtype="float32",
    vocab_pad_multiple=8,
)
