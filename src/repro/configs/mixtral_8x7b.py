"""mixtral-8x7b [moe]: 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    num_experts=8,
    experts_per_token=2,
    sliding_window=4096,
    subquadratic=True,        # SWA window bounds decode KV memory
    # 8 experts don't divide the 16-way model axis: shard expert FFNs on
    # their hidden dim (expert_mlp -> model via rule fallback) and the
    # dispatch capacity over data (see EXPERIMENTS.md Perf A1 + A3)
    sharding_overrides=(("expert_cap", ("pod", "data")),),
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    d_ff=128,
    vocab_size=512,
    num_experts=4,
    experts_per_token=2,
    sliding_window=16,
    dtype="float32",
    vocab_pad_multiple=8,
)
