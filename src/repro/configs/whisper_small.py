"""whisper-small [audio]: enc-dec, conv frontend stubbed.
[arXiv:2212.04356; unverified]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,             # decoder layers
    encoder_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    is_encoder_decoder=True,
    frontend="audio_frames",
    qkv_bias=True,
    rope_theta=0.0,            # absolute positions (sinusoid enc / learned dec)
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=2,
    encoder_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    dtype="float32",
    vocab_pad_multiple=8,
)
