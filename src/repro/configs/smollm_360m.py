"""smollm-360m [dense]: llama-arch small; 15 heads / 5 KV heads exercises the
divisibility-fallback sharding rules. [hf:HuggingFaceTB/SmolLM-135M; hf]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=49152,
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=2,
    d_model=48,
    num_heads=3,
    num_kv_heads=1,
    head_dim=16,
    d_ff=96,
    vocab_size=512,
    dtype="float32",
    vocab_pad_multiple=8,
)
