"""Architecture registry: one module per assigned config (+ the paper's own).

``get_config(name)`` returns the full published config; ``smoke_config(name)``
returns a reduced same-family config for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import (
    ALL_SHAPES,
    SHAPES,
    ModelConfig,
    ShapeConfig,
    TrainConfig,
    shape_applicable,
)

ARCH_NAMES = (
    "zamba2_7b",
    "phi35_moe",
    "mixtral_8x7b",
    "whisper_small",
    "internvl2_2b",
    "qwen25_3b",
    "granite_8b",
    "smollm_360m",
    "qwen2_72b",
    "xlstm_350m",
)

# CLI aliases matching the assignment spelling.
ALIASES = {
    "zamba2-7b": "zamba2_7b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "mixtral-8x7b": "mixtral_8x7b",
    "whisper-small": "whisper_small",
    "internvl2-2b": "internvl2_2b",
    "qwen2.5-3b": "qwen25_3b",
    "granite-8b": "granite_8b",
    "smollm-360m": "smollm_360m",
    "qwen2-72b": "qwen2_72b",
    "xlstm-350m": "xlstm_350m",
}


def get_config(name: str) -> ModelConfig:
    mod_name = ALIASES.get(name, name).replace("-", "_").replace(".", "")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def smoke_config(name: str) -> ModelConfig:
    mod_name = ALIASES.get(name, name).replace("-", "_").replace(".", "")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.SMOKE


def all_configs():
    return {n: get_config(n) for n in ARCH_NAMES}
