"""The paper's own workload as a deployable service config (§V scale).

10M sparse embeddings, M=512, ~20 nnz/row (paper Table III mid row), K=100,
k=8 per partition; partitions = one per device x sub-streams.  Used by the
dry-run cell 'topk_spmv' and the examples.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class TopKServiceConfig:
    n_rows: int = 10_000_000
    n_cols: int = 512
    mean_nnz_per_row: float = 20.0
    big_k: int = 100
    k: int = 8
    cores_per_device: int = 1
    block_size: int = 256
    value_format: str = "BF16"
    distribution: str = "gamma"


CONFIG = TopKServiceConfig()

# Reduced config for CPU smoke tests / examples.
SMOKE = TopKServiceConfig(
    n_rows=20_000, n_cols=256, mean_nnz_per_row=16.0, big_k=32, k=8,
    block_size=128, value_format="F32",
)
