"""internvl2-2b [vlm]: InternViT (stubbed patch embeddings) + InternLM2-2B.
[arXiv:2404.16821; hf]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    frontend="vision_patches",
    frontend_tokens=256,
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    frontend_tokens=8,
    dtype="float32",
    vocab_pad_multiple=8,
)
